"""Multi-tenant ResourceProvider: admission queueing, coordination
policies, quotas/reservations, and the provision-ledger invariants
(property tests via the conftest hypothesis shim)."""
from __future__ import annotations

import pytest

from tests.conftest import given, settings, st

from repro.core.policy import MgmtPolicy, PolicyEngine
from repro.core.provider import (
    CoordinatedPolicy, FirstComePolicy, ResourceProvider,
    resolve_coordination,
)
from repro.core.provision import BILL_UNIT_S, ProvisionService
from repro.core.tre import HTCRuntimeEnv, TickClock
from repro.core.types import Job, Workload
from repro.sim.engine import Sim
from repro.sim.systems import REServer, run_system


class Tenant:
    """Minimal requester: accepts up to its remaining need, logs grants."""

    def __init__(self, need: int):
        self.need = need
        self.grants: list[tuple[float, int]] = []

    def on_grant(self, offer: int, t: float) -> int:
        take = min(offer, self.need)
        self.need -= take
        if take:
            self.grants.append((t, take))
        return take


def submit(prov, name, tenant, n, t, **kw):
    return prov.submit_request(name, n, t, on_grant=tenant.on_grant, **kw)


# ----------------------------------------------------------- coordination
def test_resolve_coordination():
    assert isinstance(resolve_coordination(None), FirstComePolicy)
    assert isinstance(resolve_coordination("coordinated"), CoordinatedPolicy)
    pol = CoordinatedPolicy(starvation_s=60.0)
    assert resolve_coordination(pol) is pol
    with pytest.raises(ValueError, match="unknown coordination"):
        resolve_coordination("round-robin")


def test_immediate_grant_when_uncontended():
    prov = ResourceProvider(100)
    a = Tenant(30)
    req = submit(prov, "a", a, 30, 0.0)
    assert req.status == "granted" and req.granted == 30
    assert prov.allocated["a"] == 30 and a.grants == [(0.0, 30)]
    assert not prov.admission_queue


def test_rejected_request_parks_and_grants_on_release():
    """An indivisible (DR2-style) request that does not fit parks whole
    and lands through its callback when enough capacity frees."""
    prov = ResourceProvider(100)
    assert prov.request("a", 80, 0.0)
    b = Tenant(50)
    req = submit(prov, "b", b, 50, 1.0, min_useful=50)
    assert req.status == "queued" and b.grants == []
    prov.release("a", 20, 2.0)           # frees 20: still short of 50
    assert req.status == "queued" and b.grants == []
    prov.release("a", 40, 3.0)           # now 60 free -> deferred grant lands
    assert req.status == "granted" and b.grants == [(3.0, 50)]
    assert prov.allocated["b"] == 50
    assert not prov.admission_queue


def test_divisible_request_drains_available_capacity_eagerly():
    """A divisible (DR1-style) parked request takes whatever the pool has
    at each drain instead of idling it (work-conserving FIFO)."""
    prov = ResourceProvider(100)
    assert prov.request("a", 80, 0.0)
    b = Tenant(50)
    req = submit(prov, "b", b, 50, 1.0)
    assert req.status == "queued" and b.grants == [(1.0, 20)]
    prov.release("a", 30, 2.0)
    assert req.status == "granted" and b.grants == [(1.0, 20), (2.0, 30)]
    assert prov.allocated["b"] == 50


def test_first_come_queue_is_fifo_fair():
    """A head blocked on global capacity blocks later requests even if
    they would fit — the head soaks up every release (work-conserving
    FIFO) and completes before anything younger is served."""
    prov = ResourceProvider(100)
    prov.request("x", 90, 0.0)
    a, b = Tenant(40), Tenant(5)
    ra = submit(prov, "a", a, 10, 1.0)   # 10 free: immediate partial? no —
    assert ra.status == "granted"        # fits whole, uncontended
    ra = submit(prov, "a", a, 30, 1.5)
    rb = submit(prov, "b", b, 5, 2.0)
    prov.release("x", 20, 3.0)           # 20 free: b fits, but a is the head
    assert ra.status == "queued" and rb.status == "queued"
    assert b.grants == []
    assert a.grants[-1] == (3.0, 20)     # divisible head drains the pool
    prov.release("x", 20, 4.0)           # head completes, then b
    assert ra.status == "granted" and rb.status == "granted"
    assert a.grants[-1] == (4.0, 10) and b.grants == [(4.0, 5)]


def test_first_come_skips_tenant_capped_head():
    """A head blocked only by its own quota must not starve the fleet."""
    prov = ResourceProvider(100, quotas={"a": 10})
    a, b = Tenant(40), Tenant(30)
    ra = submit(prov, "a", a, 40, 0.0)    # over quota: can never be served
    rb = submit(prov, "b", b, 30, 1.0)
    assert rb.status == "granted" and b.grants == [(1.0, 30)]
    assert ra.status == "queued"


def test_first_come_head_blocked_by_others_reservation_is_fifo_fair():
    """A head waiting on capacity set aside by another tenant's undrawn
    reservation is shared-pool-blocked: younger requests must not keep
    overtaking it (only an own-quota block may be skipped)."""
    prov = ResourceProvider(100, reservations={"r": 30})
    a, c = Tenant(80), Tenant(40)
    ra = submit(prov, "a", a, 80, 0.0, min_useful=80)  # headroom 70: parks
    rc = submit(prov, "c", c, 40, 1.0)    # would fit, but the head blocks
    assert ra.status == "queued" and rc.status == "queued"
    assert a.grants == [] and c.grants == []
    prov.cancel(ra, 2.0)                  # head withdraws: queue re-drains
    assert ra.status == "cancelled"
    assert rc.status == "granted" and c.grants == [(2.0, 40)]


def test_release_check_regrant_cannot_oversubscribe_env():
    """An env's own release may be re-granted to its own parked request by
    the provider's drain inside provision.release(); the deficit (and the
    schedule() that follows) must see the post-release pool, or busy can
    exceed owned."""
    sim = Sim()
    prov = ResourceProvider(20, coordination="first-come")
    jobs = [Job(jid=0, arrival=0.0, runtime=200.0, nodes=10),
            Job(jid=1, arrival=70.0, runtime=5000.0, nodes=11),
            Job(jid=2, arrival=70.0, runtime=5000.0, nodes=11)]
    wl = Workload("a", "htc", jobs, trace_nodes=11, period=40000.0)
    srv = REServer(sim, wl, prov, mode="dsp",
                   policy=MgmtPolicy(2, 1.0, 60.0, 3600.0))
    sim.at(61.0, prov.request, "hog", 10, 61.0)     # platform fills
    sim.at(8000.0, prov.release, "hog", 10, 8000.0)
    checks = []
    def check():
        checks.append((srv.env.busy, srv.env.owned))
        assert srv.env.busy <= srv.env.owned, (sim.t, srv.env.busy,
                                               srv.env.owned)
    for t in (3601.0, 3660.0, 8001.0):
        sim.at(t, check)
    sim.run()
    assert len(srv.completed) == 3                  # and the TRE drains fully
    assert prov.total_allocated == 0                # everything released
    assert checks                                   # invariant was exercised


def test_stale_request_declined_not_granted():
    """A decline (take=0) never pushes nodes onto the tenant — and the
    request keeps its queue position (the live floor may merely have
    risen past this offer); only the requester's amend retires it."""
    prov = ResourceProvider(50)
    prov.request("x", 50, 0.0)
    a = Tenant(20)
    req = submit(prov, "a", a, 20, 1.0)
    assert req.status == "queued"
    a.need = 0                            # tenant's backlog drained meanwhile
    prov.release("x", 50, 2.0)
    assert req.status == "queued" and a.grants == []
    assert prov.allocated.get("a", 0) == 0
    prov.amend(req, 0, 3.0)               # the tenant's next scan retires it
    assert req.status == "cancelled" and req not in prov.admission_queue


def test_amend_cancel_drains_followers_at_amend_time():
    """Retiring a drained head via amend serves the follower at the amend
    time — not at the head's stale submission time (a lease backdated
    hours would overbill and break the alloc curve's time order)."""
    prov = ResourceProvider(50)
    prov.request("x", 50, 0.0)
    a, b = Tenant(30), Tenant(10)
    ra = submit(prov, "a", a, 30, 100.0, min_useful=30)
    rb = submit(prov, "b", b, 10, 200.0, min_useful=10)
    prov.release("x", 20, 3000.0)         # head (30 > 20) still blocks b
    assert b.grants == []
    prov.amend(ra, 0, 5000.0)             # head's need vanished
    assert ra.status == "cancelled"
    assert rb.status == "granted" and b.grants == [(5000.0, 10)]
    ts = [t for t, _ in prov._alloc_curve]
    assert ts == sorted(ts)               # curve stays time-ordered


def test_cancel_without_drain_serves_nobody():
    """Teardown detach: cancelling with drain=False must not hand the
    freed queue position to anyone (the envs are about to be destroyed)."""
    prov = ResourceProvider(50)
    prov.request("x", 50, 0.0)
    a, b = Tenant(30), Tenant(10)
    ra = submit(prov, "a", a, 30, 100.0, min_useful=30)
    rb = submit(prov, "b", b, 10, 200.0, min_useful=10)
    prov.release("x", 20, 3000.0)
    prov.cancel(ra, 5000.0, drain=False)
    assert ra.status == "cancelled"
    assert rb.status == "queued" and b.grants == []


def test_below_floor_decline_keeps_fifo_position():
    """An offer below the requester's *live* floor is declined without
    losing the parked request's FIFO position or starvation age."""
    prov = ResourceProvider(100, coordination="coordinated")
    prov.request("x", 100, 0.0)
    grants = []

    def picky(offer, t):                  # live floor rose to 30 meanwhile
        if offer < 30:
            return 0
        grants.append((t, offer))
        return offer

    req = prov.submit_request("p", 40, 1.0, on_grant=picky)
    assert req.status == "queued"
    prov.release("x", 10, 2.0)            # water-fill offers 10 -> declined
    assert req.status == "queued" and req in prov.admission_queue
    assert grants == []
    prov.release("x", 30, 3.0)            # 40 free -> whole grant accepted
    assert req.status == "granted" and grants == [(3.0, 40)]


def test_amend_updates_cancels_and_completes():
    prov = ResourceProvider(50)
    prov.request("x", 40, 0.0)
    a = Tenant(30)
    req = submit(prov, "a", a, 30, 1.0, min_useful=30)
    assert req.status == "queued"
    a.need = 10
    prov.amend(req, 10, 2.0, 10)          # smaller need now fits (10 free)
    assert req.status == "granted" and a.grants == [(2.0, 10)]
    b = Tenant(30)
    rb = submit(prov, "b", b, 30, 3.0, min_useful=30)
    prov.amend(rb, 0, 4.0)                # need vanished -> cancelled
    assert rb.status == "cancelled" and rb not in prov.admission_queue


def test_amend_priority_only_change_redrains():
    """Regression: a priority-only amend must re-drain. Before the fix,
    ``changed`` ignored priority, so an urgency bump under coordinated
    arbitration could not unblock a parked request (e.g. one declined in
    an earlier drain whose tenant's backlog refilled at the same width)
    until an unrelated release happened to trigger a drain."""
    prov = ResourceProvider(50, coordination="coordinated")
    prov.request("x", 50, 0.0)
    grants = []
    backlog = {"empty": True}

    def on_grant(offer, t):               # declines while backlog is empty
        if backlog["empty"]:
            return 0
        grants.append((t, offer))
        return offer

    req = prov.submit_request("p", 20, 1.0, on_grant=on_grant)
    assert req.status == "queued"
    prov.release("x", 30, 2.0)            # drain offers 20 -> declined
    assert req.status == "queued" and grants == []
    backlog["empty"] = False              # the tenant's queue refilled,
    # same width, higher urgency — the scan amends priority only
    prov.amend(req, 20, 3.0, min_useful=1, priority=4.0)
    assert req.priority == 4.0
    assert req.status == "granted" and grants == [(3.0, 20)]


def test_amend_same_request_without_priority_does_not_drain():
    """The no-change fast path survives the priority fix: re-amending an
    identical (n, min_useful) with priority=None must not drain (the env
    re-scans every 3 s; a drain per no-op amend would re-offer declined
    requests every scan)."""
    prov = ResourceProvider(50)
    prov.request("x", 50, 0.0)
    calls = []
    req = prov.submit_request("p", 20, 1.0,
                              on_grant=lambda o, t: calls.append(t) or 0)
    prov.release("x", 30, 2.0)            # declined once (take=0)
    n_calls = len(calls)
    prov.amend(req, 20, 3.0, min_useful=1)            # no-op amend
    assert len(calls) == n_calls          # no fresh drain, no re-offer
    prov.amend(req, 20, 4.0, min_useful=1, priority=req.priority)
    assert len(calls) == n_calls          # same priority: still a no-op


def test_cancel_with_empty_alloc_curve_falls_back_to_submit_time():
    """Regression: ``cancel(req, t=None)`` backdate-guarded against
    ``_alloc_curve[-1]`` and raised IndexError when no allocation event
    had been recorded; it must fall back to the request's own submission
    time instead."""
    prov = ResourceProvider(10)
    prov.request("x", 10, 0.0)
    a, b = Tenant(10), Tenant(4)
    ra = submit(prov, "a", a, 10, 5.0, min_useful=10)
    rb = submit(prov, "b", b, 4, 6.0, min_useful=4)
    prov.release("x", 4, 7.0)             # head (10 > 4) still blocks b
    prov._alloc_curve.clear()             # no allocation event on record
    prov.cancel(ra)                       # t=None: must not IndexError
    assert ra.status == "cancelled"
    # the follower's grant lands at the *cancelled head's* submission
    # time — the only defensible floor with an empty event log
    assert rb.status == "granted" and b.grants == [(5.0, 4)]


def test_quota_and_reservation_headroom():
    prov = ResourceProvider(100, quotas={"a": 60},
                            reservations={"r": 30})
    # a's headroom: 100 free minus r's undrawn 30, capped by quota 60
    assert prov.headroom("a") == min(100 - 30, 60)
    assert prov.headroom("r") == 100      # may draw everything incl. its own
    assert prov.request("a", 60, 0.0)
    assert not prov.request("a", 1, 1.0)  # quota exhausted
    assert prov.headroom("a") == 0
    # r's reservation survives: 40 left, none reserved away from r
    assert prov.headroom("r") == 40
    b = Tenant(20)
    # 40 free - 30 reserved for r = 10 headroom: an indivisible 20 parks
    req = submit(prov, "b", b, 20, 2.0, min_useful=20)
    assert req.status == "queued"
    assert prov.request("r", 30, 3.0)     # r draws its guarantee
    assert prov.headroom("b") == 10


def test_reservations_must_fit_capacity():
    with pytest.raises(ValueError, match="reservations exceed capacity"):
        ResourceProvider(10, reservations={"a": 8, "b": 8})


def test_coordinated_serves_most_urgent_first():
    prov = ResourceProvider(100, coordination="coordinated")
    prov.request("x", 100, 0.0)           # platform full: both park
    calm, urgent = Tenant(30), Tenant(30)
    r1 = submit(prov, "calm", calm, 30, 1.0, priority=1.5)
    r2 = submit(prov, "urgent", urgent, 30, 2.0, priority=9.0)
    prov.release("x", 30, 3.0)            # room for exactly one whole grant
    assert r2.status == "granted" and urgent.grants == [(3.0, 30)]
    assert r1.status == "queued" and calm.grants == []


def test_coordinated_water_fills_contended_backlog():
    """When no whole request fits, the remaining capacity is split in
    fair shares instead of parking behind a wide head."""
    prov = ResourceProvider(100, coordination="coordinated")
    prov.request("x", 100, 0.0)           # platform full: both park
    a, b = Tenant(40), Tenant(40)
    ra = submit(prov, "a", a, 40, 1.0, priority=2.0)
    rb = submit(prov, "b", b, 40, 1.0, priority=2.0)
    prov.release("x", 30, 2.0)            # 30 free, two 40-wide requests
    assert a.grants == [(2.0, 15)] and b.grants == [(2.0, 15)]
    assert ra.status == "queued" and rb.status == "queued"
    assert ra.nodes == 25 and rb.nodes == 25   # remainders stay parked


def test_coordinated_respects_min_useful():
    """An indivisible (DR2-style) request is never served below its
    useful floor — a partial grant would idle until reclaimed."""
    prov = ResourceProvider(100, coordination="coordinated")
    prov.request("x", 90, 0.0)
    wide = Tenant(40)
    req = submit(prov, "wide", wide, 40, 1.0, min_useful=40)
    prov.release("x", 20, 2.0)            # 30 free < 40: nothing offered
    assert wide.grants == [] and req.status == "queued"
    prov.release("x", 20, 3.0)            # 50 free >= 40
    assert wide.grants == [(3.0, 40)] and req.status == "granted"


def test_starving_elder_reserves_capacity():
    """Past the starvation age, released capacity accumulates for the
    elder instead of being water-filled to younger requests."""
    prov = ResourceProvider(
        100, coordination=CoordinatedPolicy(starvation_s=10.0))
    prov.request("x", 100, 0.0)
    wide = Tenant(60)
    young = Tenant(30)
    rw = submit(prov, "wide", wide, 60, 0.0, min_useful=60)
    prov.release("x", 40, 50.0)           # elder (age 50) reserves its 60
    ry = submit(prov, "young", young, 30, 50.0)
    assert young.grants == [] and ry.status == "queued"
    prov.release("x", 30, 60.0)           # 70 free: elder finally fits
    assert wide.grants == [(60.0, 60)] and rw.status == "granted"
    # leftovers flow to the younger request once the elder is served
    assert young.grants == [(60.0, 10)]


def test_direct_request_cannot_overtake_parked_fifo_head():
    """Satellite regression (fails pre-fix): the direct grant-or-reject
    path used to check only live headroom, so a lifecycle creation or
    DRP burst could take the very capacity a FIFO head was parked
    waiting for — overtaking a request it should queue behind. The
    direct path must be arbitration-aware: denied while a parked elder
    of another tenant has a prior claim on the shared pool."""
    prov = ResourceProvider(100, coordination="first-come")
    prov.request("x", 90, 0.0)
    a = Tenant(50)
    ra = submit(prov, "a", a, 50, 1.0, min_useful=50)   # 10 free: parks
    prov.release("x", 20, 2.0)            # 30 free < 50: head still blocked
    assert ra.status == "queued"
    # pre-fix this succeeded (20 <= 30 live headroom) and starved the head
    assert not prov.request("drp", 20, 3.0)
    assert prov.allocated.get("drp", 0) == 0
    # the head's own tenant overtakes nothing by drawing directly
    assert prov.request("a", 10, 4.0)
    prov.release("x", 60, 5.0)            # head finally fits and completes
    assert ra.status == "granted" and a.grants == [(5.0, 50)]
    # queue empty again: the direct path reopens
    assert prov.request("drp", 20, 6.0)


def test_direct_request_respects_starving_coordinated_elder():
    """Coordinated arbitration re-plans every drain, so only a *starving*
    elder (whose useful floor the arbiter is already reserving out of
    free capacity) hardens a claim against the direct path — a young
    parked request does not."""
    prov = ResourceProvider(
        100, coordination=CoordinatedPolicy(starvation_s=10.0))
    prov.request("x", 100, 0.0)
    wide = Tenant(60)
    rw = submit(prov, "wide", wide, 60, 0.0, min_useful=60)
    prov.release("x", 40, 50.0)           # elder (age 50) reserves its 60
    assert rw.status == "queued"
    # pre-fix this drained the capacity accumulating for the elder
    assert not prov.request("drp", 30, 51.0)
    prov.release("x", 20, 60.0)           # 60 free: elder served
    assert rw.status == "granted" and wide.grants == [(60.0, 60)]
    prov.release("x", 10, 61.0)           # queue empty: direct path reopens
    assert prov.request("drp", 10, 62.0)

    young = ResourceProvider(
        100, coordination=CoordinatedPolicy(starvation_s=1e9))
    young.request("x", 100, 0.0)
    w2 = Tenant(60)
    submit(young, "wide", w2, 60, 0.0, min_useful=60)
    young.release("x", 40, 50.0)          # parked, but nowhere near starving
    assert young.request("drp", 30, 51.0)


def test_direct_request_own_reservation_senior_to_parked_claim():
    """A tenant's guaranteed minimum is exactly the capacity no parked
    elder can speak for: drawing it directly stays possible while a
    foreign head is parked on the shared pool."""
    prov = ResourceProvider(100, coordination="first-come",
                            reservations={"r": 30})
    prov.request("x", 70, 0.0)
    a = Tenant(50)
    ra = submit(prov, "a", a, 50, 1.0, min_useful=50)
    assert ra.status == "queued"          # headroom 30 - debt 30 = 0
    assert prov.request("r", 30, 2.0)     # the reservation is senior
    assert not prov.request("drp", 1, 3.0)   # everyone else still queues


def test_plain_service_rejects_without_queueing():
    prov = ProvisionService(50)
    a = Tenant(40)
    prov.request("x", 20, 0.0)
    req = submit(prov, "a", a, 40, 1.0)
    assert req.status == "rejected" and a.grants == []
    ok = submit(prov, "a", a, 30, 2.0)
    assert ok.status == "granted" and a.grants == [(2.0, 30)]


# ------------------------------------------------- env integration (sim)
def test_deferred_grant_wakes_queued_env_on_release():
    """The tentpole end-to-end: TRE b's DR1 is parked by a full platform
    and lands through the admission queue the moment TRE a releases —
    not at b's next scan."""
    sim = Sim()
    prov = ResourceProvider(20, coordination="first-come")
    jobs_a = [Job(jid=0, arrival=0.0, runtime=4000.0, nodes=12)]
    wl_a = Workload("a", "htc", jobs_a, trace_nodes=12, period=20000.0)
    jobs_b = [Job(jid=0, arrival=0.0, runtime=600.0, nodes=14)]
    wl_b = Workload("b", "htc", jobs_b, trace_nodes=14, period=20000.0)
    # a: B=12, runs immediately; b: B=4, needs DR2=10 > free 4 -> parks
    REServer(sim, wl_a, prov, mode="dsp", policy=MgmtPolicy.htc(12, 100.0))
    srv_b = REServer(sim, wl_b, prov, mode="dsp",
                     policy=MgmtPolicy.htc(4, 1.0))
    sim.run()
    assert len(srv_b.completed) == 1
    job_b = srv_b.completed[0]
    # a's lifetime: [0, 4000] + destroy; b's wide job cannot start before
    # a's destroy released the platform (deferred grant, not a scan poll)
    assert job_b.start >= 4000.0
    assert prov.total_allocated == 0     # both TREs destroyed, all released


def test_env_amend_keeps_parked_request_fresh():
    clock = TickClock()
    prov = ResourceProvider(20, coordination="first-come")
    prov.request("x", 16, 0.0)
    started = []
    env = HTCRuntimeEnv("t", provision=prov, clock=clock,
                        launch=started.append, policy=MgmtPolicy.htc(2, 1.0))
    env.submit(Job(jid=0, arrival=0.0, runtime=50.0, nodes=6))
    clock.advance()
    env.scan()                            # DR1 needs 4, only 2 free: parks
    assert env._pending_req is not None
    assert env._pending_req.status == "queued"
    env.queue.clear()                     # demand vanishes
    clock.advance()
    env.scan()                            # amend with need 0 -> cancelled
    assert env._pending_req is None and not prov.admission_queue


def test_run_system_quota_scenario_caps_each_tenant():
    jobs = [Job(jid=i, arrival=0.0, runtime=7200.0, nodes=4)
            for i in range(4)]
    wl = Workload("q", "htc", jobs, trace_nodes=8, period=14400.0)
    res = run_system("dawningcloud-quota", [wl],
                     policies={"q": MgmtPolicy.htc(4, 1.0)})
    assert res.per_workload["q"].completed_total == 4
    # demand is 16 wide, but the quota pins the TRE at its cluster size —
    # and the tenant still grows all the way TO the quota (a quota-capped
    # divisible request is served partially, not starved at B)
    assert res.peak_nodes_per_hour == 8


# ------------------------------------------------------- property tests
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 40),
                          st.booleans()), min_size=1, max_size=40),
       st.integers(30, 120))
@settings(max_examples=60)
def test_capacity_never_exceeded_with_admission_queue(ops, capacity):
    """Under arbitrary submit/release interleavings (both coordination
    policies), total allocation never exceeds capacity and all ledger
    state stays consistent."""
    for coordination in ("first-come", "coordinated"):
        prov = ResourceProvider(capacity, coordination=coordination)
        tenants: dict[str, Tenant] = {}
        t = 0.0
        for who, n, is_release in ops:
            t += 60.0
            name = f"t{who}"
            if is_release and prov.allocated.get(name, 0) >= n:
                prov.release(name, n, t)
            elif not is_release:
                tenant = Tenant(n)
                tenants.setdefault(name, tenant)
                prov.submit_request(name, n, t, on_grant=tenant.on_grant)
            assert prov.total_allocated <= capacity
            assert all(v >= 0 for v in prov.allocated.values())
        # the admission queue holds only still-queued requests
        assert all(r.status == "queued" for r in prov.admission_queue)


@given(st.lists(st.tuples(st.integers(1, 50), st.booleans()), min_size=1,
                max_size=30))
@settings(max_examples=60)
def test_billed_at_least_worked(ops):
    """Per-started-hour billing can never undercut the actual node-time
    integral of the leases."""
    prov = ProvisionService()
    t = 0.0
    worked = 0.0                          # node-seconds actually held
    held_since: list[tuple[float, int]] = []
    for n, is_release in ops:
        t += 137.0
        if is_release and prov.allocated.get("a", 0) >= n:
            prov.release("a", n, t)
        elif not is_release:
            assert prov.request("a", n, t)
    worked = sum((l.t1 - l.t0) * l.nodes for l in prov.closed_leases)
    worked += sum((t - l.t0) * l.nodes
                  for blocks in prov.open_leases.values() for l in blocks)
    assert prov.node_hours("a", now=t) * BILL_UNIT_S >= worked - 1e-6


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 30),
                          st.booleans()), min_size=1, max_size=40))
@settings(max_examples=60)
def test_lifo_release_splitting_conserves_nodes(ops):
    """Closing newest blocks first (with partial-release splits) loses no
    nodes: open blocks match live allocation, open+closed match grants."""
    prov = ProvisionService(capacity=10_000)
    granted: dict[str, int] = {}
    released: dict[str, int] = {}
    t = 0.0
    for who, n, is_release in ops:
        t += 60.0
        name = f"t{who}"
        if is_release and prov.allocated.get(name, 0) >= n:
            prov.release(name, n, t)
            released[name] = released.get(name, 0) + n
        elif not is_release:
            assert prov.request(name, n, t)
            granted[name] = granted.get(name, 0) + n
    for name in granted:
        open_nodes = sum(l.nodes for l in prov.open_leases.get(name, []))
        closed_nodes = sum(l.nodes for l in prov.closed_leases
                           if l.tre == name)
        assert open_nodes == prov.allocated.get(name, 0)
        assert open_nodes == granted[name] - released.get(name, 0)
        assert closed_nodes == released.get(name, 0)


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 30),
                          st.booleans()), min_size=1, max_size=40),
       st.floats(0, 5e4))
@settings(max_examples=40)
def test_vectorized_accounting_matches_loop_reference(ops, extra):
    prov = ProvisionService(capacity=10_000)
    t = 0.0
    for who, n, is_release in ops:
        t += 311.0
        name = f"t{who}"
        if is_release and prov.allocated.get(name, 0) >= n:
            prov.release(name, n, t)
        elif not is_release:
            prov.request(name, n, t)
    now = t + extra
    assert prov.node_hours(None, now=now) == \
        prov.node_hours_loop(None, now=now)
    assert prov.node_hours("t0", now=now) == \
        prov.node_hours_loop("t0", now=now)
    assert prov.peak_nodes_per_hour(now) == \
        prov.peak_nodes_per_hour_loop(now)


@given(st.lists(st.integers(1, 30), min_size=2, max_size=8),
       st.integers(40, 80))
@settings(max_examples=60)
def test_admission_queue_drains_fifo_fair(needs, capacity):
    """First-come: deferred requests complete in submission order — a
    later request never completes before an earlier one (quotas unset)."""
    prov = ResourceProvider(capacity, coordination="first-come")
    prov.request("hog", capacity, 0.0)
    order: list[int] = []
    reqs = []
    for i, n in enumerate(needs):
        def make(i=i, n=n):
            def on_grant(offer, t, *, _i=i, _n=n):
                take = min(offer, _n)
                order.append(_i)
                return take
            return on_grant
        reqs.append(prov.submit_request(f"t{i}", n, float(i + 1),
                                        on_grant=make()))
    assert all(r.status == "queued" for r in reqs)
    # release everything in dribs: grants must land oldest-first
    for step in range(capacity):
        if prov.allocated.get("hog", 0) > 0:
            prov.release("hog", 1, 100.0 + step)
    assert order == sorted(order)
    assert all(r.status == "granted" for r in reqs)


# -------------------------------------------------- drain re-entrancy
def _reentrancy_invariants(prov, reqs, accepted):
    """The ledger/queue consistency a mid-drain side effect must never
    break: no double-grant (the provider's ledger matches what each
    requester actually accepted), no orphaned ``queued`` status (a
    request is in the admission queue IFF its status says so), and the
    pool is never oversubscribed."""
    assert prov.total_allocated <= (prov.capacity or 1 << 31)
    assert all(v >= 0 for v in prov.allocated.values())
    in_queue = set(map(id, prov.admission_queue))
    for req in reqs:
        assert (req.status == "queued") == (id(req) in in_queue), \
            (req.tre, req.status)
        assert req.granted == accepted.get(req.seq, 0), \
            (req.tre, req.granted, accepted.get(req.seq, 0))
    per_tre: dict[str, int] = {}
    for seq, take in accepted.items():
        req = next(r for r in reqs if r.seq == seq)
        per_tre[req.tre] = per_tre.get(req.tre, 0) + take
    for tre, total in per_tre.items():
        assert prov.allocated.get(tre, 0) == total, (tre,)


def _run_reentrant_drain(ops, coordination, capacity=60):
    """Submit parked requests whose ``on_grant`` callbacks amend / cancel
    / priority-bump ANOTHER parked request mid-drain, then free capacity
    in dribs so every drain interleaves with the side effects."""
    prov = ResourceProvider(capacity, coordination=coordination)
    prov.request("hog", capacity, 0.0)
    reqs: list = []
    accepted: dict[int, int] = {}
    need_left: dict[int, int] = {}

    def make(slot: int, victim: int, action: str):
        def on_grant(offer: float, t: float) -> int:
            req = reqs[slot]
            take = min(offer, need_left[slot])
            need_left[slot] -= take
            if take:
                accepted[req.seq] = accepted.get(req.seq, 0) + take
            target = reqs[victim] if victim < len(reqs) else None
            if target is not None and target is not req \
                    and target.status == "queued":
                if action == "amend":
                    prov.amend(target, max(target.nodes - 1, 1), t,
                               min_useful=1)
                elif action == "cancel":
                    prov.cancel(target, t)
                elif action == "bump":
                    prov.amend(target, target.nodes, t,
                               min_useful=target.min_useful, priority=9.0)
            return take
        return on_grant

    t = 1.0
    for i, (need, victim, action) in enumerate(ops):
        need_left[i] = need
        req = prov.submit_request(f"t{i}", need, t,
                                  on_grant=make(i, victim, action))
        reqs.append(req)
        _reentrancy_invariants(prov, reqs, accepted)
        t += 1.0
    for step in range(capacity):
        if prov.allocated.get("hog", 0) == 0:
            break
        prov.release("hog", 1, 100.0 + step)
        _reentrancy_invariants(prov, reqs, accepted)
    return prov, reqs, accepted


@given(st.lists(st.tuples(st.integers(1, 25), st.integers(0, 11),
                          st.sampled_from(["none", "amend", "cancel",
                                           "bump"])),
                min_size=2, max_size=12),
       st.sampled_from(["first-come", "coordinated"]))
@settings(max_examples=50, deadline=None)
def test_property_drain_reentrant_side_effects_keep_ledger_consistent(
        ops, coordination):
    """For all interleavings of grants whose callbacks amend, cancel or
    priority-bump OTHER parked requests mid-drain: no double-grant, no
    orphaned ``queued`` status, pool never oversubscribed."""
    prov, reqs, accepted = _run_reentrant_drain(ops, coordination)
    _reentrancy_invariants(prov, reqs, accepted)


def test_drain_reentrant_cancel_and_amend_deterministic():
    """Shim-proof companion: a grant callback that cancels one victim and
    bumps/amends others mid-drain leaves the queue consistent. Under
    first-come the FIFO head is served first, so its cancel fires before
    the victim ever receives a grant; under coordinated the water-fill
    may legitimately serve the victim first, so only the consistency
    invariants are pinned there."""
    ops = [(10, 1, "cancel"),     # t0's grant cancels t1
           (20, 2, "amend"),      # t1's grant (never lands) amends t2
           (30, 0, "bump"),       # t2's grant bumps t0 (already done)
           (5, 0, "none")]
    prov, reqs, accepted = _run_reentrant_drain(ops, "first-come")
    _reentrancy_invariants(prov, reqs, accepted)
    assert reqs[1].status == "cancelled" and reqs[1].granted == 0
    assert reqs[1] not in prov.admission_queue
    assert reqs[0].status == "granted" and reqs[0].granted == 10
    assert reqs[3].status == "granted"
    assert prov.allocated.get("t2", 0) == accepted.get(reqs[2].seq, 0)

    prov, reqs, accepted = _run_reentrant_drain(ops, "coordinated")
    _reentrancy_invariants(prov, reqs, accepted)
    assert reqs[1].status in ("cancelled", "granted", "queued")
    assert reqs[1].granted == accepted.get(reqs[1].seq, 0)


# ----------------------------------------------------- PolicyEngine DR split
def test_scan_request_dr1_floor_dr2_indivisible():
    eng = PolicyEngine(MgmtPolicy.htc(10, 1.2))
    # DR1 backlog: useful floor = what the narrowest queued job would
    # need even with everything owned free
    assert eng.scan_request([30, 30], 10) == (50, 20)
    assert eng.scan_request([], 10) == (0, 0)
    eng14 = PolicyEngine(MgmtPolicy.htc(4, 1.0))
    # a single wide job via DR1 is as indivisible as via DR2
    assert eng14.scan_request([14], 4) == (10, 10)
    # a narrow job in the mix lowers the floor to its own deficit
    assert eng14.scan_request([6, 14], 4) == (16, 2)
    # jobs already narrower than owned: any grant relieves contention
    assert eng14.scan_request([2, 3, 4], 4) == (5, 1)
    # DR2 (ratio below R, one oversized job) -> all-or-nothing
    eng2 = PolicyEngine(MgmtPolicy.htc(40, 2.0))
    assert eng2.scan_request([64], 40) == (24, 24)


def test_urgency_is_obtaining_ratio():
    eng = PolicyEngine(MgmtPolicy.htc(10, 1.2))
    assert eng.urgency([30, 30], 20) == 3.0
    assert eng.urgency([], 20) == 0.0
    assert eng.urgency([5], 0) == 5.0     # owned floor of 1
