"""The unified control plane: RuntimeEnv under both drivers, the System
registry, conservative backfill, and the §3.1.3 lifecycle routing."""
from __future__ import annotations

import pytest

from repro.core.lifecycle import LifecycleService, TREState
from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.core.registry import (
    System, available_systems, get_system, register_system,
)
from repro.core.registry import _REGISTRY
from repro.core.scheduling import (
    SCHEDULERS, backfill, easy_backfill, resolve_scheduler,
)
from repro.core.controller import ElasticController, TrainTask
from repro.core.tre import HTCRuntimeEnv, TickClock
from repro.core.types import Job, Workload
from repro.sim.engine import Sim
from repro.sim.systems import REServer, run_system
from repro.sim.traces import montage_like


# ----------------------------------------------------- emulator/live parity
PARITY_POLICY = MgmtPolicy(initial=2, ratio=1.2, scan_interval=60.0,
                           release_interval=300.0)
# (nodes, sim runtime seconds, live optimizer segments, sim arrival seconds,
#  live submit-before tick). Runtimes sit strictly between scan ticks so the
# discrete emulator and the tick-driven controller observe every finish at
# the same scan; wave 2 (12 nodes) forces a DR1 grant, and the second release
# window frees the first dynamic block in both drivers.
PARITY_JOBS = [
    ("a", 4, 80.0, 2, 30.0, 1),
    ("b", 3, 140.0, 3, 30.0, 1),
    ("c", 2, 200.0, 4, 30.0, 1),
    ("d", 12, 50.0, 1, 330.0, 6),
]


class _FakeSegmentController(ElasticController):
    """ElasticController with the JAX training segment stubbed out: control
    decisions (the thing under test) all live in the shared RuntimeEnv."""

    def _run_segment(self, task, fail=False):
        task.steps_done = min(task.steps_done + self.steps_per_tick,
                              task.num_steps)


def _parity_deltas(prov: ProvisionService, name: str) -> list[int]:
    return [e.delta for e in prov.adjust_events if e.tre == name]


def _run_parity_sim() -> tuple[list[int], list[str]]:
    jobs = [Job(jid=i, arrival=arr, runtime=rt, nodes=n, name=name)
            for i, (name, n, rt, _steps, arr, _tick) in enumerate(PARITY_JOBS)]
    wl = Workload("parity", "htc", jobs, trace_nodes=16, period=900.0)
    sim = Sim()
    prov = ProvisionService()
    REServer(sim, wl, prov, mode="dsp", policy=PARITY_POLICY,
             hold_until=900.0)
    sim.run()
    done = sorted(jobs, key=lambda j: j.finish)
    return _parity_deltas(prov, "parity"), [j.name for j in done]


def _run_parity_live() -> tuple[list[int], list[str]]:
    prov = ProvisionService()
    ctl = _FakeSegmentController(
        policy=PARITY_POLICY, provision=prov, tre_name="parity",
        devices=[object()] * 16, steps_per_tick=1, ticks_per_release=5,
        elastic_grow=False)
    tasks = {tick: [] for _, _, _, _, _, tick in PARITY_JOBS}
    for name, n, _rt, steps, _arr, tick in PARITY_JOBS:
        tasks[tick].append(TrainTask(name, rcfg=None, nodes=n,
                                     num_steps=steps, ckpt_dir=""))
    for k in range(1, 13):
        for t in tasks.get(k, ()):
            ctl.submit(t)
        ctl.tick()
    assert len(ctl.finished) == len(PARITY_JOBS)
    ctl.destroy()
    return _parity_deltas(prov, "parity"), [t.name for t in ctl.finished]


def test_emulator_live_parity_decisions():
    """The same HTCRuntimeEnv under the sim clock and under the live
    ElasticController must make identical request/release decisions on the
    same job stream: initial grant, DR1 grants, idle-window releases and
    the final lifecycle destroy, in the same order."""
    sim_deltas, sim_order = _run_parity_sim()
    live_deltas, live_order = _run_parity_live()
    assert sim_deltas == live_deltas
    assert sim_order == live_order
    # the stream exercises grant AND release paths, not just the no-ops
    assert [d for d in sim_deltas if d > 0] == [2, 7, 3]
    assert [d for d in sim_deltas if d < 0] == [-7, -5]


def test_parity_dynamic_blocks_agree():
    sim = Sim()
    prov_s = ProvisionService()
    jobs = [Job(jid=i, arrival=arr, runtime=rt, nodes=n, name=name)
            for i, (name, n, rt, _steps, arr, _t) in enumerate(PARITY_JOBS)]
    wl = Workload("parity", "htc", jobs, trace_nodes=16, period=900.0)
    srv = REServer(sim, wl, prov_s, mode="dsp", policy=PARITY_POLICY,
                   hold_until=900.0)
    sim.run(until=700.0)     # after the release window, before destruction
    prov_l = ProvisionService()
    ctl = _FakeSegmentController(
        policy=PARITY_POLICY, provision=prov_l, tre_name="parity",
        devices=[object()] * 16, steps_per_tick=1, ticks_per_release=5,
        elastic_grow=False)
    for k in range(1, 12):
        for name, n, _rt, steps, _arr, tick in PARITY_JOBS:
            if tick == k:
                ctl.submit(TrainTask(name, rcfg=None, nodes=n,
                                     num_steps=steps, ckpt_dir=""))
        ctl.tick()
    assert srv.env.engine.dynamic_blocks == ctl.env.engine.dynamic_blocks
    assert srv.env.owned == ctl.env.owned


def test_run_max_ticks_flushes_final_tick_completions():
    """A task finishing exactly on the max_ticks boundary must still be
    reported to the env (freeing its nodes) and reach ctl.finished."""
    prov = ProvisionService()
    ctl = _FakeSegmentController(
        policy=MgmtPolicy.htc(2, 1.0), provision=prov, tre_name="flush",
        devices=[object()] * 4, steps_per_tick=1, ticks_per_release=0,
        elastic_grow=False)
    task = TrainTask("t", rcfg=None, nodes=1, num_steps=3, ckpt_dir="")
    ctl.submit(task)
    ctl.run(max_ticks=3)            # done in tick 3 == the cutoff
    assert ctl.finished == [task] and task.done
    assert ctl.env.busy == 0        # no phantom load left behind
    assert not ctl._done_last_tick


def test_run_max_ticks_leaves_backlog_queued_not_running():
    """The cutoff flush must not hand freshly-launched work to a driver
    that has stopped ticking: backlog stays in the queue, resumable by a
    later run(), instead of sitting in running with phantom busy nodes."""
    prov = ProvisionService()
    ctl = _FakeSegmentController(
        policy=MgmtPolicy.htc(1, 1.0), provision=prov, tre_name="cutoff",
        devices=[object()], steps_per_tick=1, ticks_per_release=0,
        elastic_grow=False)
    a = TrainTask("a", rcfg=None, nodes=1, num_steps=3, ckpt_dir="")
    b = TrainTask("b", rcfg=None, nodes=1, num_steps=2, ckpt_dir="")
    ctl.submit(a)
    ctl.submit(b)
    ctl.run(max_ticks=3)            # a finishes on the cutoff, b still queued
    assert ctl.finished == [a]
    assert ctl.env.queue == [b] and not ctl.running
    assert ctl.env.busy == 0 and b.steps_done == 0
    ctl.run()                       # resumable: b trains to completion
    assert ctl.finished == [a, b] and b.done
    assert ctl.env.busy == 0


def test_live_backfill_gets_release_profile_from_estimates():
    """The controller stamps tick-domain runtime estimates at submit, so a
    live TRE with scheduler="backfill" really backfills (strict FCFS would
    head-of-line-block the narrow task behind the wide one)."""
    prov = ProvisionService()
    ctl = _FakeSegmentController(
        policy=MgmtPolicy.htc(4, 100.0), provision=prov, tre_name="bf-live",
        devices=[object()] * 4, steps_per_tick=1, ticks_per_release=0,
        elastic_grow=False, scheduler="backfill")
    t_long = TrainTask("long", rcfg=None, nodes=3, num_steps=5, ckpt_dir="")
    t_wide = TrainTask("wide", rcfg=None, nodes=4, num_steps=1, ckpt_dir="")
    t_fill = TrainTask("fill", rcfg=None, nodes=1, num_steps=1, ckpt_dir="")
    for t in (t_long, t_wide, t_fill):
        ctl.submit(t)
    ctl.tick()
    # fill (1 node, 1 tick) slips in front of the blocked 4-node head
    # without delaying its reservation at the long task's release — it ran
    # its single segment this very tick (strict FCFS would have left it
    # queued behind wide)
    assert {t.name for t in ctl.running} == {"long"}
    assert [t.name for t in ctl._done_last_tick] == ["fill"]
    assert ctl.env.queue == [t_wide]
    ctl.run()
    assert {t.name for t in ctl.finished} == {"long", "wide", "fill"}
    assert ctl.env.busy == 0


# ------------------------------------------------------------ idle accounting
def test_idle_state_explicit_from_creation():
    """Idle accounting fields are explicit __init__ state (not lazy getattr
    defaults) and integrate from TRE creation, so a scan-granted block's
    pre-activity idle is visible to the first release check."""
    clock = TickClock()
    prov = ProvisionService()
    env = HTCRuntimeEnv("idle-tre", provision=prov, clock=clock,
                        launch=lambda task: None,
                        policy=MgmtPolicy.htc(4, 1.2))
    assert env._idle_acc == 0.0 and env._idle_t == 0.0
    assert env._release_t == 0.0
    clock.advance(10.0)
    env._account_idle()
    assert env._idle_acc == 40.0        # 4 nodes idle for 10 units


def test_release_uses_time_averaged_idle():
    clock = TickClock()
    prov = ProvisionService()
    started = []
    env = HTCRuntimeEnv("avg-tre", provision=prov, clock=clock,
                        launch=started.append,
                        policy=MgmtPolicy.htc(1, 1.0))
    env.submit(Job(jid=0, arrival=0.0, runtime=5.0, nodes=6))
    clock.advance()
    assert env.scan() == 5              # DR1: demand 6 vs owned 1
    [job] = started
    clock.advance(2.0)
    env.finish(job)                     # 6 nodes busy over [1, 3)
    # at t=10 the average idle over [0, 10) is (1*1 + 0*2 + 6*7)/10 = 4.3
    # -> int 4 < block 5: keep (instantaneous idle is 6, avg filters it)
    clock.advance(7.0)
    assert env.release_check() == 0
    # next window [10, 20) is fully idle: avg 6 >= 5 -> release the block
    clock.advance(10.0)
    assert env.release_check() == 5
    assert prov.allocated["avg-tre"] == 1   # B is never reclaimed


def test_finish_frees_grown_allocation():
    clock = TickClock()
    prov = ProvisionService()
    started = []
    env = HTCRuntimeEnv("grow-tre", provision=prov, clock=clock,
                        launch=started.append,
                        policy=MgmtPolicy.htc(8, 1.0))
    job = Job(jid=0, arrival=0.0, runtime=5.0, nodes=2)
    env.submit(job)
    clock.advance()
    env.scan()
    assert started == [job] and env.busy == 2
    assert env._reserved[id(job)] == (6.0, 2)    # release profile recorded
    env.grow(job, 4)
    assert env.busy == 6 and env.free == 2
    env.shrink(job, 1)
    assert env.busy == 5
    # the profile tracks resizes, so backfill never sees a stale deficit
    assert env._reserved[id(job)] == (6.0, 5)
    env.finish(job)
    assert env.busy == 0                # grown allocation fully returned
    assert id(job) not in env._reserved


# ----------------------------------------------------------------- lifecycle
def test_env_creation_routes_through_lifecycle():
    prov = ProvisionService(capacity=100)
    lc = LifecycleService(prov)
    clock = TickClock()
    env = HTCRuntimeEnv("lc-tre", provision=prov, clock=clock,
                        launch=lambda t: None, policy=MgmtPolicy.htc(10, 1.2),
                        lifecycle=lc)
    rec = lc.tres["lc-tre"]
    assert rec.state == TREState.RUNNING
    assert [(frm, to) for _, frm, to in rec.history] == [
        ("inexistent", "planning"), ("planning", "created"),
        ("created", "running")]
    env.destroy()
    assert rec.state == TREState.INEXISTENT
    assert prov.allocated["lc-tre"] == 0
    env.destroy()                        # idempotent: no double transition
    assert rec.history[-1][2] == "inexistent"


def test_env_creation_rejected_walks_back_to_inexistent():
    prov = ProvisionService(capacity=5)
    lc = LifecycleService(prov)
    with pytest.raises(RuntimeError, match="rejected"):
        HTCRuntimeEnv("big-tre", provision=prov, clock=TickClock(),
                      launch=lambda t: None, policy=MgmtPolicy.htc(10, 1.2),
                      lifecycle=lc)
    rec = lc.tres["big-tre"]
    assert rec.state == TREState.INEXISTENT
    assert [(frm, to) for _, frm, to in rec.history] == [
        ("inexistent", "planning"), ("planning", "inexistent")]
    assert prov.total_allocated == 0


def test_emulation_run_exercises_lifecycle():
    jobs = [Job(jid=0, arrival=0.0, runtime=600.0, nodes=4)]
    wl = Workload("tiny", "htc", jobs, trace_nodes=8, period=7200.0)
    sim = Sim()
    prov = ProvisionService()
    lc = LifecycleService(prov)
    srv = REServer(sim, wl, prov, mode="fixed", fixed_nodes=8,
                   hold_until=wl.period, lifecycle=lc)
    sim.run()
    rec = lc.tres["tiny"]
    assert rec.state == TREState.INEXISTENT       # destroyed at window end
    assert rec.destroyed_t == wl.period
    assert srv.destroyed and len(srv.completed) == 1


def test_dcs_deploy_is_not_an_adjustment_ssp_lease_is():
    jobs = [Job(jid=0, arrival=0.0, runtime=60.0, nodes=2)]
    wl = Workload("t", "htc", jobs, trace_nodes=4, period=3600.0)
    dcs = run_system("dcs", [wl])
    ssp = run_system("ssp", [wl])
    # DCS owns its configuration: neither deploy nor withdrawal is a node
    # adjustment (§4.5.4); SSP leases, so both ends of the lease count
    assert dcs.adjust_count == 0
    assert ssp.adjust_count == 8


# ------------------------------------------------------------------ registry
def test_registry_knows_all_usage_models():
    assert {"dcs", "ssp", "drp", "dawningcloud", "dawningcloud-backfill",
            "dawningcloud-easy"} <= set(available_systems())
    assert get_system("dawningcloud").name == "dawningcloud"


def test_unknown_system_rejected():
    with pytest.raises(ValueError, match="unknown system"):
        run_system("phoenixcloud", [montage_like()])


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @register_system("dcs")
        class Clash(System):
            pass

    @register_system("tmp-replaceable")
    class Tmp(System):
        pass

    @register_system("tmp-replaceable", replace=True)
    class Tmp2(System):
        pass

    assert isinstance(get_system("tmp-replaceable"), Tmp2)
    del _REGISTRY["tmp-replaceable"]


def test_new_scenario_is_a_plugin():
    """A new usage model needs only a registered System — run_system picks
    it up with zero dispatch edits (the PhoenixCloud extension axis)."""
    from repro.sim.systems import DawningCloudSystem

    @register_system("frugal-dsp", replace=True)
    class FrugalDSP(DawningCloudSystem):
        def default_policy(self, wl):
            return (MgmtPolicy.htc(1, 1.0) if wl.kind == "htc"
                    else MgmtPolicy.mtc(1, 1.0))

    try:
        jobs = [Job(jid=i, arrival=0.0, runtime=600.0, nodes=2)
                for i in range(3)]
        wl = Workload("w", "htc", jobs, trace_nodes=8, period=7200.0)
        res = run_system("frugal-dsp", [wl])
        assert res.per_workload["w"].completed_total == 3
        assert res.system == "frugal-dsp"
    finally:
        del _REGISTRY["frugal-dsp"]


# ------------------------------------------------------------------ backfill
def _j(jid, nodes, runtime):
    return Job(jid=jid, arrival=0.0, runtime=runtime, nodes=nodes)


def test_backfill_registered():
    assert SCHEDULERS["backfill"] is backfill
    assert resolve_scheduler("backfill", "htc") is backfill
    with pytest.raises(ValueError, match="unknown scheduler"):
        resolve_scheduler("sjf", "htc")


def test_backfill_fills_behind_blocked_head():
    queue = [_j(0, 50, 100.0), _j(1, 10, 40.0), _j(2, 10, 200.0)]
    # 30 free now; 30 more released at t=100 -> head reserves [100, 200)
    started = backfill(queue, 30, now=0.0, running=((100.0, 30),), busy=30)
    # the 10-node jobs never dip the profile below the head's 50 at t=100
    assert [j.jid for j in started] == [1, 2]


def test_backfill_never_delays_reserved_head():
    queue = [_j(0, 50, 100.0), _j(1, 15, 200.0)]
    started = backfill(queue, 30, now=0.0, running=((100.0, 30),), busy=30)
    # job 1 would still hold 15 nodes at t=100, leaving 45 < 50 for the
    # head's reservation -> it must wait
    assert started == []


def test_backfill_degrades_to_fcfs_without_release_profile():
    queue = [_j(0, 50, 100.0), _j(1, 10, 40.0)]
    # busy nodes whose release times are unknown: refuse to gamble
    assert backfill(queue, 30, now=0.0, running=(), busy=30) == []
    # ...but with full information it backfills
    assert backfill(queue, 30, now=0.0, running=((50.0, 30),), busy=30) \
        == [queue[1]]


def test_backfill_plain_start_when_everything_fits():
    queue = [_j(0, 4, 60.0), _j(1, 2, 60.0)]
    assert backfill(queue, 8, now=0.0, running=(), busy=0) == queue


def test_scheduler_override_through_system_api():
    """Per-workload scheduler override via run_system(schedulers=...): the
    conservative-backfill TRE refuses a long narrow job that would delay
    the blocked wide head; the default first-fit TRE starts it eagerly."""
    def mk():
        return Workload("bf", "htc", [
            Job(jid=0, arrival=0.0, runtime=7000.0, nodes=2),
            Job(jid=1, arrival=120.0, runtime=600.0, nodes=4),   # wide head
            Job(jid=2, arrival=180.0, runtime=20000.0, nodes=2),
        ], trace_nodes=4, period=14400.0)

    pol = {"bf": MgmtPolicy.htc(4, 100.0)}    # never grows: pure scheduling
    bf = run_system("dawningcloud", [mk()], policies=pol,
                    schedulers={"bf": "backfill"})
    ff = run_system("dawningcloud", [mk()], policies=pol)
    assert bf.per_workload["bf"].completed_total == 3
    assert ff.per_workload["bf"].completed_total == 3
    # first-fit lets job 2 jump in and delay the head ~20000 s; backfill
    # holds it back, so the head's (and mean) wait is far smaller
    assert bf.per_workload["bf"].mean_wait_s < ff.per_workload["bf"].mean_wait_s


# -------------------------------------------------------------- EASY backfill
def test_easy_registered():
    assert SCHEDULERS["easy"] is easy_backfill
    assert resolve_scheduler("easy", "htc") is easy_backfill


def test_easy_never_delays_reserved_head():
    """The EASY guarantee: the blocked head's reserved start is
    inviolable. A fill whose runtime would eat into the head's node
    reservation at its shadow time must be refused."""
    queue = [_j(0, 35, 100.0), _j(1, 30, 250.0)]
    # 30 free now, 30 more at t=100 -> head (35 wide) reserves t=100;
    # the 30-node fill would leave only 60-30=30 < 35 there -> refused
    assert easy_backfill(queue, 30, now=0.0, running=((100.0, 30),),
                         busy=30) == []
    # a fill that fits under the head's reservation may start
    queue2 = [_j(0, 35, 100.0), _j(1, 20, 250.0)]
    assert easy_backfill(queue2, 30, now=0.0, running=((100.0, 30),),
                         busy=30) == [queue2[1]]


def test_easy_fills_where_conservative_refuses():
    """EASY reserves ONLY the head: a fill that would push back a
    mid-queue job's (conservative) reservation still starts, because EASY
    grants that job no reservation — the aggressive/conservative split."""
    queue = [_j(0, 35, 100.0), _j(1, 40, 100.0), _j(2, 22, 250.0)]
    assert backfill(queue, 30, now=0.0, running=((100.0, 30),),
                    busy=30) == []                       # job 1's slot held
    assert easy_backfill(queue, 30, now=0.0, running=((100.0, 30),),
                         busy=30) == [queue[2]]          # EASY fills


def test_easy_degrades_to_fcfs_without_release_profile():
    queue = [_j(0, 50, 100.0), _j(1, 10, 40.0)]
    assert easy_backfill(queue, 30, now=0.0, running=(), busy=30) == []
    assert easy_backfill(queue, 30, now=0.0, running=((50.0, 30),),
                         busy=30) == [queue[1]]


def test_easy_plain_start_when_everything_fits():
    queue = [_j(0, 4, 60.0), _j(1, 2, 60.0)]
    assert easy_backfill(queue, 8, now=0.0, running=(), busy=0) == queue


def test_dawningcloud_easy_scenario_head_start_matches_conservative():
    """dawningcloud-easy runs consolidated and keeps the conservative
    variant's head guarantee: the blocked wide head starts no later than
    under conservative backfill, while the long narrow job behind it is
    still held off the head's reservation."""
    def mk():
        return Workload("bf", "htc", [
            Job(jid=0, arrival=0.0, runtime=7000.0, nodes=2),
            Job(jid=1, arrival=120.0, runtime=600.0, nodes=4),   # wide head
            Job(jid=2, arrival=180.0, runtime=20000.0, nodes=2),
        ], trace_nodes=4, period=14400.0)

    pol = {"bf": MgmtPolicy.htc(4, 100.0)}    # never grows: pure scheduling
    easy = run_system("dawningcloud-easy", [mk()], policies=pol)
    cons = run_system("dawningcloud-backfill", [mk()], policies=pol)
    assert easy.per_workload["bf"].completed_total == 3
    # identical decisions on this stream: the head job (jid 1) starts at
    # the long job's release in both variants
    assert easy.per_workload["bf"].mean_wait_s == \
        cons.per_workload["bf"].mean_wait_s


def test_dawningcloud_backfill_scenario_runs_consolidated():
    wl_mtc = montage_like()
    jobs = [Job(jid=0, arrival=0.0, runtime=3000.0, nodes=6),
            Job(jid=1, arrival=60.0, runtime=600.0, nodes=2)]
    wl_htc = Workload("mini", "htc", jobs, trace_nodes=8, period=7200.0)
    res = run_system("dawningcloud-backfill", [wl_htc, wl_mtc],
                     policies={"mini": MgmtPolicy.htc(4, 2.0)})
    assert res.per_workload["mini"].completed_total == 2
    assert res.per_workload["montage"].completed_total == 1000
    # MTC dependencies still respected under the consolidated mix
    assert res.per_workload["montage"].node_hours >= 166
