"""Training substrate: optimizer math, checkpoints, fault-tolerant loop,
microbatch-accumulation equivalence."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.loop import Preemption, train_loop
from repro.train.optimizer import AdamW, TrainState
from repro.train.train_step import build_train_step
from repro.data.synthetic import synthetic_batches
from repro.models.lm import LM
from tests.conftest import smoke_runconfig


# --------------------------------------------------------------- optimizer
def test_adamw_matches_reference_step():
    opt = AdamW(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
                grad_clip=1e9, warmup_steps=0, total_steps=10**9,
                min_lr_frac=1.0, moment_dtype="float32")
    p0 = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.asarray([0.1, -0.2, 0.3], jnp.float32)}
    state = opt.init(p0)
    state, metrics = opt.apply(state, g)
    # reference: bias-corrected adam, step 1 => update = lr * sign-ish
    m = 0.1 * np.asarray([0.1, -0.2, 0.3])
    v = 0.05 * np.asarray([0.1, -0.2, 0.3]) ** 2
    u = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.95)) + 1e-8)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(p0["w"]) - 1e-2 * u, rtol=1e-5)
    assert metrics["lr"] == pytest.approx(1e-2)


def test_grad_clip_caps_global_norm():
    opt = AdamW(grad_clip=1.0, warmup_steps=0, moment_dtype="float32")
    p = {"w": jnp.zeros((3,), jnp.float32)}
    g = {"w": jnp.asarray([30.0, 40.0, 0.0])}   # norm 50
    state = opt.init(p)
    _, metrics = opt.apply(state, g)
    assert float(metrics["grad_norm"]) == pytest.approx(50.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(opt.schedule(jnp.asarray(0))) == 0.0
    assert float(opt.schedule(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(opt.schedule(jnp.asarray(110))) == pytest.approx(0.1)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.asarray([1.5], jnp.float32),
                  "s": jnp.asarray(3, jnp.int32)}}
    d = str(tmp_path)
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, tree, keep=2)
    assert ckpt.latest_step(d) == 40
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = ckpt.restore(d, like)
    assert step == 40
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, restored)
    # gc kept only 2
    assert len([p for p in tmp_path.iterdir() if p.name.startswith("step_")]) == 2


def test_checkpoint_wrong_structure_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(AssertionError):
        ckpt.restore(d, {"a": jnp.zeros((2,)), "b": jnp.zeros((1,))})


# ---------------------------------------------------------------- the loop
def test_loop_failure_recovery_and_progress(tmp_ckpt):
    rcfg = smoke_runconfig("qwen2-7b", total_steps=24)
    rep = train_loop(rcfg, ckpt_dir=tmp_ckpt, num_steps=24, ckpt_every=8,
                     fail_at={13: True, 19: True})
    assert rep.restarts == 2
    assert rep.losses, "no steps ran"
    assert rep.final_loss < rep.losses[0]


def test_loop_gives_up_after_max_restarts(tmp_ckpt):
    rcfg = smoke_runconfig("qwen2-7b", total_steps=4)
    with pytest.raises(Preemption):
        train_loop(rcfg, ckpt_dir=tmp_ckpt, num_steps=4, ckpt_every=100,
                   fail_at={1: True}, max_restarts=0)


def test_microbatch_accumulation_matches_full_batch():
    """grads(microbatched) == grads(full batch) up to accumulation dtype."""
    import dataclasses
    from repro.configs.base import ShapeConfig
    rcfg1 = dataclasses.replace(smoke_runconfig("granite-3-8b"),
                                shape=ShapeConfig("mb", "train", 32, 8))
    rcfg2 = dataclasses.replace(
        rcfg1, parallel=dataclasses.replace(rcfg1.parallel, microbatches=4))
    lm = LM(rcfg1.model)
    params = lm.init(jax.random.key(0))[0]
    batch = synthetic_batches(rcfg1)(0)
    outs = []
    for rcfg in (rcfg1, rcfg2):
        step_fn, rt, opt = build_train_step(lm, rcfg)
        state = opt.init(params)
        state2, metrics = jax.jit(step_fn)(state, batch)
        outs.append((float(metrics["loss"]), state2.params))
    assert outs[0][0] == pytest.approx(outs[1][0], rel=2e-2)
    flat1 = jnp.concatenate([x.ravel().astype(jnp.float32)
                             for x in jax.tree.leaves(outs[0][1])])
    flat2 = jnp.concatenate([x.ravel().astype(jnp.float32)
                             for x in jax.tree.leaves(outs[1][1])])
    # parameter updates should be nearly identical
    assert float(jnp.max(jnp.abs(flat1 - flat2))) < 5e-2


def test_preempt_resume_losses_bit_identical(tmp_path):
    """The preempt/resume parity contract (tests/README.md): a run
    interrupted by ``Preemption`` mid-run and resumed from
    ``latest_step`` produces step-for-step bit-identical losses to an
    uninterrupted run — fails if ANY state (params, optimizer moments,
    schedule step, data order) escapes the checkpoint. This is the real
    counterpart of the emulated checkpoint-rollback model in
    ``repro.serve.tenant.TrainTenant``."""
    rcfg = smoke_runconfig("qwen2-7b", total_steps=12)
    ref = train_loop(rcfg, ckpt_dir=str(tmp_path / "ref"), num_steps=12,
                     ckpt_every=4)
    rep = train_loop(rcfg, ckpt_dir=str(tmp_path / "pre"), num_steps=12,
                     ckpt_every=4, fail_at={6: True})
    assert rep.restarts == 1
    # attempt 1 ran steps 0..5 and died before step 6; the resume
    # restored the step-4 checkpoint and replayed 4..11
    assert len(rep.losses) == 6 + 8
    # pre-preemption losses match the reference exactly
    assert rep.losses[:6] == ref.losses[:6]
    # the replayed + resumed tail is bit-identical to the uninterrupted
    # trajectory from the checkpoint step on — float ==, no tolerance
    assert rep.losses[6:] == ref.losses[4:]
    assert rep.final_loss == ref.final_loss


def test_loss_decreases_over_training(tmp_ckpt):
    rcfg = smoke_runconfig("mamba2-1.3b", total_steps=40,
                           learning_rate=3e-3)
    rep = train_loop(rcfg, ckpt_dir=tmp_ckpt, num_steps=40, ckpt_every=0)
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.1, (first, last)
