"""Pin every assigned architecture's config to the assignment table."""
from __future__ import annotations

import pytest

from repro.configs import ARCHS, SHAPES, get_config

# (layers, d_model, heads, kv, d_ff, vocab) + family extras
ASSIGNED = {
    "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                        d_ff=4864, vocab_size=32000, moe=True, n_experts=128,
                        top_k=2, dense_residual=True),
    "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                            n_kv_heads=8, vocab_size=163840, moe=True,
                            n_experts=384, top_k=8, d_ff_expert=2048),
    "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                 n_kv_heads=8, d_ff=24576, vocab_size=65536,
                                 moe=True, n_experts=16, top_k=2, ssm=True,
                                 attn_layer_period=8),
    "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12800, vocab_size=49155),
    "qwen2-7b": dict(n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
                     d_ff=18944, vocab_size=152064, qkv_bias=True),
    "nemotron-4-15b": dict(n_layers=32, d_model=6144, n_heads=48,
                           n_kv_heads=8, d_ff=24576, vocab_size=256000,
                           mlp_act="sq_relu"),
    "qwen3-14b": dict(n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
                      d_ff=17408, vocab_size=151936, qk_norm=True),
    "mamba2-1.3b": dict(n_layers=48, d_model=2048, d_ff=0, vocab_size=50280,
                        ssm=True, d_state=128, attn_layer_period=0),
    "internvl2-76b": dict(n_layers=80, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=28672, vocab_size=128256,
                          vision_stub=True),
    "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32,
                           n_kv_heads=32, d_ff=8192, vocab_size=2048,
                           n_codebooks=4),
}

EXPECTED_PARAMS_B = {  # loose sanity bands (billions)
    "arctic-480b": (400, 560), "kimi-k2-1t-a32b": (900, 1150),
    "jamba-1.5-large-398b": (330, 450), "granite-3-8b": (7, 10),
    "qwen2-7b": (6, 9), "nemotron-4-15b": (13, 18),
    "qwen3-14b": (12, 17), "mamba2-1.3b": (1.1, 1.6),
    "internvl2-76b": (65, 85),
    # swiglu MLP (3-matrix) at the assigned d_ff=8192 -> ~3.3B
    "musicgen-large": (2.5, 3.6),
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    for field, want in ASSIGNED[arch].items():
        assert getattr(cfg, field) == want, (arch, field)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_in_expected_band(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.1f}B outside [{lo}, {hi}]B"


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    active = kimi.param_count(active=True) / 1e9
    assert 25 <= active <= 40, active   # "a32b"


def test_shape_cells():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["prefill_32k"].tokens == 32768 * 32
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
