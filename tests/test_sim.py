"""Emulator behaviour + trace-generator calibration tests."""
from __future__ import annotations

import math

import numpy as np
import pytest

from tests.conftest import given, settings, st

from repro.core.policy import MgmtPolicy
from repro.core.provision import ProvisionService
from repro.core.types import Job, Workload
from repro.sim.engine import Sim
from repro.sim.systems import DRPRunner, REServer, run_system
from repro.sim.traces import (
    montage_like, nasa_ipsc_like, sdsc_blue_like, _self_throttle,
)


# ------------------------------------------------------------------ engine
def test_event_order_stable():
    sim = Sim()
    seen = []
    sim.at(5.0, lambda: seen.append("b"))
    sim.at(1.0, lambda: seen.append("a"))
    sim.at(5.0, lambda: seen.append("c"))   # same time: scheduling order
    sim.run()
    assert seen == ["a", "b", "c"]
    assert sim.t == 5.0


def test_run_until():
    sim = Sim()
    seen = []
    sim.at(10.0, lambda: seen.append(1))
    sim.run(until=5.0)
    assert seen == [] and sim.t == 5.0
    sim.run()
    assert seen == [1]


# ------------------------------------------------------------------- traces
def test_nasa_trace_calibration():
    wl = nasa_ipsc_like()
    assert len(wl.jobs) == 2603
    assert wl.trace_nodes == 128
    assert wl.max_job_nodes <= 128
    assert abs(wl.utilization() - 0.466) < 1e-6
    assert all(j.nodes in (1, 2, 4, 8, 16, 32, 64, 128) for j in wl.jobs)


def test_blue_trace_calibration():
    wl = sdsc_blue_like()
    assert len(wl.jobs) == 2649
    assert wl.max_job_nodes <= 144
    # the documented target utilization is realized exactly (runtimes are
    # rescaled onto it) and matches the default the docstring quotes
    assert abs(wl.utilization() - 0.51) < 1e-6
    # week 2 is the busy half
    mid = wl.period / 2
    w1 = sum(1 for j in wl.jobs if j.arrival < mid)
    assert w1 < len(wl.jobs) / 2.8


def test_montage_dag():
    wl = montage_like()
    assert len(wl.jobs) == 1000
    assert abs(np.mean([j.runtime for j in wl.jobs]) - 11.38) < 1e-6
    byid = {j.jid: j for j in wl.jobs}
    # acyclic: deps always reference earlier ids (topological by build)
    for j in wl.jobs:
        assert all(d < j.jid for d in j.deps)
    # stage widths from the paper reconstruction
    names = [j.name.split("-")[0] for j in wl.jobs]
    assert names.count("mProjectPP") == 166
    assert names.count("mDiffFit") == 662
    assert names.count("mBackground") == 166


def test_traces_deterministic_per_seed():
    a, b = nasa_ipsc_like(7), nasa_ipsc_like(7)
    c = nasa_ipsc_like(8)
    assert [(j.arrival, j.nodes, j.runtime) for j in a.jobs] == \
           [(j.arrival, j.nodes, j.runtime) for j in b.jobs]
    assert [(j.arrival) for j in a.jobs] != [(j.arrival) for j in c.jobs]


@given(st.lists(st.tuples(st.floats(0, 1e5), st.floats(1, 5e3),
                          st.integers(1, 64)), min_size=1, max_size=60),
       st.integers(64, 128))
@settings(max_examples=40)
def test_self_throttle_respects_cap(raw, cap):
    jobs = [Job(jid=i, arrival=a, runtime=r, nodes=n)
            for i, (a, r, n) in enumerate(raw)]
    orig = {j.jid: j.arrival for j in jobs}
    _self_throttle(jobs, cap)
    # arrivals only move later
    assert all(j.arrival >= orig[j.jid] - 1e-9 for j in jobs)
    # eager concurrency never exceeds cap
    events = sorted([(j.arrival, j.nodes) for j in jobs]
                    + [(j.arrival + j.runtime, -j.nodes) for j in jobs])
    cur = 0
    for _, d in events:
        cur += d
        assert cur <= cap + 1e-9


# ------------------------------------------------------------------ systems
def _tiny_workload():
    jobs = [Job(jid=0, arrival=0.0, runtime=600.0, nodes=4),
            Job(jid=1, arrival=0.0, runtime=600.0, nodes=4),
            Job(jid=2, arrival=3600.0, runtime=600.0, nodes=8)]
    return Workload("tiny", "htc", jobs, trace_nodes=8, period=7200.0)


def test_dcs_billing_is_config_times_period():
    res = run_system("dcs", [_tiny_workload()])
    r = res.per_workload["tiny"]
    assert r.node_hours == 8 * 2      # 8 nodes x ceil(7200 s) = 2 h
    assert r.completed_total == 3


def test_drp_bills_each_job_hour_rounded():
    res = run_system("drp", [_tiny_workload()])
    r = res.per_workload["tiny"]
    # three leases: 4, 4, 8 nodes x 1 started hour each
    assert r.node_hours == 16
    assert r.completed_total == 3
    assert res.peak_nodes_per_hour == 8   # two 4-node jobs overlap


def test_dawningcloud_grows_and_completes():
    wl = _tiny_workload()
    res = run_system("dawningcloud", [wl],
                     policies={"tiny": MgmtPolicy.htc(2, 1.2)})
    r = res.per_workload["tiny"]
    assert r.completed_total == 3
    # grew beyond the initial 2 nodes to run the 8-node job
    assert res.peak_nodes_per_hour >= 8
    # and billed less than DRP + initial (sanity ceiling)
    assert r.node_hours <= 16 + 2 * math.ceil(res.window_s / 3600)


def test_montage_dsp_converges_to_dcs_width():
    """Paper §4.5.2: with B10_R8 the MTC TRE resizes to the DCS config."""
    wl = montage_like()
    res_dc = run_system("dawningcloud", [wl],
                        policies={"montage": MgmtPolicy.mtc(10, 8.0)})
    res_dcs = run_system("dcs", [wl], mtc_fixed_nodes=166)
    assert res_dc.per_workload["montage"].node_hours == \
        res_dcs.per_workload["montage"].node_hours == 166
    tps_dc = res_dc.per_workload["montage"].tasks_per_second
    tps_dcs = res_dcs.per_workload["montage"].tasks_per_second
    assert abs(tps_dc - tps_dcs) / tps_dcs < 0.02


def test_workflow_dependencies_respected():
    wl = montage_like()
    run_system("dcs", [wl], mtc_fixed_nodes=166)
    byid = {j.jid: j for j in wl.jobs}
    for j in wl.jobs:
        for d in j.deps:
            assert byid[d].finish <= j.start + 1e-6, (j.name, d)


def test_consolidated_three_providers():
    wls = [nasa_ipsc_like(), sdsc_blue_like(), montage_like()]
    res = run_system("dawningcloud", wls)
    assert set(res.per_workload) == {"nasa", "blue", "montage"}
    assert all(r.completed_total == len(w.jobs)
               for w, r in zip(wls, res.per_workload.values()))
    # headline directional claims of the paper
    dcs = run_system("dcs", wls, mtc_fixed_nodes=166)
    assert res.total_node_hours < dcs.total_node_hours
    assert res.peak_nodes_per_hour <= 1.25 * dcs.peak_nodes_per_hour


def test_ssp_and_dcs_same_performance_different_adjusts():
    wls = [_tiny_workload()]
    ssp = run_system("ssp", wls)
    dcs = run_system("dcs", wls)
    assert (ssp.per_workload["tiny"].node_hours
            == dcs.per_workload["tiny"].node_hours)
    assert ssp.adjust_count > dcs.adjust_count  # SSP leases, DCS owns
