"""Unit + property tests for the DSP model (policies, provision, lifecycle,
scheduling) — the paper's §3 semantics."""
from __future__ import annotations

import math

import pytest

from tests.conftest import given, settings, st

from repro.core.lifecycle import LifecycleService, TREState
from repro.core.policy import MgmtPolicy, PolicyEngine
from repro.core.provision import BILL_UNIT_S, ProvisionService
from repro.core.scheduling import backfill, fcfs, first_fit
from repro.core.types import Job


# ------------------------------------------------------------------ policy
def test_dr1_fires_on_threshold():
    eng = PolicyEngine(MgmtPolicy.htc(40, 1.2))
    # demand 60 vs owned 40: ratio 1.5 > 1.2 -> DR1 = 20
    assert eng.scan([30, 30], 40) == 20
    # demand 44 vs owned 40: ratio 1.1 <= 1.2, biggest 30 fits -> nothing
    assert eng.scan([30, 14], 40) == 0


def test_dr2_fires_for_oversized_job():
    eng = PolicyEngine(MgmtPolicy.htc(40, 2.0))
    # ratio 64/40 = 1.6 <= 2.0 but biggest job 64 > owned -> DR2 = 24
    assert eng.scan([64], 40) == 24


def test_dr1_has_priority_over_dr2():
    eng = PolicyEngine(MgmtPolicy.htc(10, 1.2))
    # ratio 130/10 = 13 > 1.2 -> DR1 = 120 (not DR2 = 90)
    assert eng.scan([100, 30], 10) == 120


def test_release_blocks_lifo_within_idle():
    eng = PolicyEngine(MgmtPolicy.htc(10, 1.2))
    eng.granted(30)
    eng.granted(50)
    assert eng.release_check(60) == 50       # only the 50 fits
    assert eng.dynamic_blocks == [30]
    assert eng.release_check(100) == 30
    assert eng.release_check(100) == 0        # nothing dynamic left


def test_empty_queue_requests_nothing():
    eng = PolicyEngine(MgmtPolicy.mtc(10, 8.0))
    assert eng.scan([], 10) == 0


@given(st.lists(st.integers(1, 128), max_size=40), st.integers(1, 256))
def test_policy_request_never_negative(demands, owned):
    eng = PolicyEngine(MgmtPolicy.htc(10, 1.2))
    req = eng.scan(demands, owned)
    assert req >= 0
    if req:
        # a grant always covers either the whole backlog or the biggest job
        assert owned + req in (sum(demands), max(demands))


@given(st.lists(st.integers(1, 100), max_size=20), st.integers(0, 500))
def test_release_never_exceeds_idle_or_blocks(blocks, idle):
    eng = PolicyEngine(MgmtPolicy.htc(10, 1.2))
    for b in blocks:
        eng.granted(b)
    rel = eng.release_check(idle)
    assert 0 <= rel <= min(idle, sum(blocks))
    assert rel + eng.dynamic_total == sum(blocks)


# --------------------------------------------------------------- provision
def test_grant_reject_at_capacity():
    prov = ProvisionService(capacity=100)
    assert prov.request("a", 60, 0.0)
    assert not prov.request("b", 60, 0.0)    # rejected, state unchanged
    assert prov.total_allocated == 60
    assert prov.request("b", 40, 0.0)


def test_billing_per_started_hour():
    prov = ProvisionService()
    prov.request("a", 10, 0.0)
    prov.release("a", 10, 1800.0)            # half an hour -> billed 1 h
    assert prov.node_hours("a") == 10
    prov.request("a", 4, 0.0)
    prov.release("a", 4, 2 * BILL_UNIT_S + 1)  # 2h+1s -> billed 3 h
    assert prov.node_hours("a") == 10 + 12


def test_partial_release_splits_blocks():
    prov = ProvisionService()
    prov.request("a", 10, 0.0)
    prov.request("a", 20, 0.0)
    prov.release("a", 25, 3600.0)            # closes 20 + 5 of the 10
    assert prov.allocated["a"] == 5
    assert prov.node_hours("a", now=3600.0) == 25 + 5


@given(st.lists(st.tuples(st.integers(1, 50), st.booleans()), min_size=1,
                max_size=30))
@settings(max_examples=60)
def test_provision_conservation(ops):
    """Allocation is conserved: granted - released == allocated, and the
    ledger bills every lease at least one hour."""
    prov = ProvisionService(capacity=10_000)
    granted = released = 0
    t = 0.0
    for n, is_release in ops:
        t += 60.0
        if is_release and prov.allocated.get("a", 0) >= n:
            prov.release("a", n, t)
            released += n
        elif not is_release:
            assert prov.request("a", n, t)
            granted += n
    assert prov.allocated.get("a", 0) == granted - released
    assert prov.total_allocated == granted - released
    assert prov.node_hours("a", now=t) >= granted - released
    assert prov.adjust_count() == granted + released


def test_peak_nodes_per_hour():
    prov = ProvisionService()
    prov.request("a", 10, 0.0)
    prov.request("a", 30, 1800.0)
    prov.release("a", 40, 7200.0)
    assert prov.peak_nodes() == 40
    assert prov.peak_nodes_per_hour(7200.0) == 40


# ---------------------------------------------------------------- lifecycle
def test_tre_lifecycle_happy_path():
    prov = ProvisionService(capacity=100)
    svc = LifecycleService(prov)
    rec = svc.apply("tre-a", "htc", MgmtPolicy.htc(10, 1.2), t=0.0)
    assert rec.state == TREState.RUNNING
    assert prov.allocated["tre-a"] == 10
    svc.destroy("tre-a", t=3600.0)
    assert rec.state == TREState.INEXISTENT
    assert prov.allocated["tre-a"] == 0


def test_tre_rejected_when_no_capacity():
    prov = ProvisionService(capacity=5)
    svc = LifecycleService(prov)
    rec = svc.apply("tre-a", "htc", MgmtPolicy.htc(10, 1.2), t=0.0)
    assert rec is None
    assert svc.tres["tre-a"].state == TREState.INEXISTENT


def test_invalid_transition_raises():
    prov = ProvisionService()
    svc = LifecycleService(prov)
    svc.apply("a", "htc", MgmtPolicy.htc(1, 1.0), t=0.0)
    with pytest.raises(ValueError):
        svc.apply("a", "htc", MgmtPolicy.htc(1, 1.0), t=1.0)
    with pytest.raises(ValueError):
        svc.tres["a"].transition(TREState.PLANNING, 2.0)


def test_unknown_kind_rejected():
    svc = LifecycleService(ProvisionService())
    with pytest.raises(ValueError):
        svc.apply("x", "web", MgmtPolicy.htc(1, 1.0), t=0.0)


# --------------------------------------------------------------- scheduling
def _jobs(sizes):
    return [Job(jid=i, arrival=0.0, runtime=60.0, nodes=n)
            for i, n in enumerate(sizes)]


def test_first_fit_skips_blocked_head():
    started = first_fit(_jobs([50, 10, 20]), free=30)
    assert [j.nodes for j in started] == [10, 20]


def test_fcfs_blocks_at_head():
    started = fcfs(_jobs([50, 10, 20]), free=30)
    assert started == []
    started = fcfs(_jobs([10, 50, 20]), free=30)
    assert [j.nodes for j in started] == [10]


@given(st.lists(st.integers(1, 64), max_size=30), st.integers(0, 256),
       st.lists(st.tuples(st.floats(1, 100), st.integers(1, 32)),
                max_size=8))
def test_schedulers_never_oversubscribe(sizes, free, running):
    # a complete release profile so backfill exercises its reservation
    # math rather than the degrade-to-FCFS guard
    busy = sum(n for _, n in running)
    for sched in (first_fit, fcfs, backfill):
        started = sched(_jobs(sizes), free, now=0.0,
                        running=tuple(running), busy=busy)
        assert sum(j.nodes for j in started) <= free
        # started jobs appear in queue order
        ids = [j.jid for j in started]
        assert ids == sorted(ids)
